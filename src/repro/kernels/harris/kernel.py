"""Tunable Harris-corner-detection Pallas TPU kernel.

TPU-native stencil strategy (DESIGN.md 2.1): the grid walks full-width row
*bands* of rows_step = 8*t_x*t_z rows.  The 2-row halo each band needs
(3x3 Sobel then 3x3 box = radius 2) is fetched through two extra 8-row
BlockSpecs of the same input whose index maps point at the neighbouring
8-row slabs — no overlapping BlockSpec tricks, no redundant full-band
reads.  Column halo is materialized in-register by zero-padding the band
(full image width lives in VMEM, so there is no horizontal DMA halo at
all — this is the part that differs most from the paper's OpenCL kernel,
where work-groups tile both axes; see DESIGN.md 'what changed').

Row-region splits (w_x) reorder the band traversal with clamped indices.
The 3x3 convolutions are computed as shift-and-add over the VMEM band —
MXU-free, pure VPU work, like the cost model assumes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import KernelGeometry, clamped_index, split_grid, use_interpret
from .ref import HARRIS_K


def _shift_conv3(w: jnp.ndarray, kern) -> jnp.ndarray:
    """3x3 'valid' convolution of a zero-padded window via shift-and-add.

    w: (H + 2, W + 2) -> (H, W).  kern is a 3x3 nested tuple of floats.
    Matches conv semantics (kernel flipped), i.e. output[i,j] =
    sum_{di,dj} kern[di][dj] * w[i + 2 - di, j + 2 - dj]... simplified here
    because all our kernels are symmetric or antisymmetric: we use
    cross-correlation and pass pre-flipped kernels (Sobel/box are their own
    flip up to sign conventions used consistently with the oracle).
    """
    h, wd = w.shape[0] - 2, w.shape[1] - 2
    out = jnp.zeros((h, wd), dtype=w.dtype)
    for di in range(3):
        for dj in range(3):
            c = kern[di][dj]
            if c == 0.0:
                continue
            out = out + c * w[di : di + h, dj : dj + wd]
    return out


# cross-correlation forms that reproduce conv(SOBEL_X/Y) in the oracle:
# conv flips the kernel; SOBEL_X flipped = -SOBEL_X mirrored -> precomputed.
_SOBEL_X_XCORR = ((1.0, 0.0, -1.0), (2.0, 0.0, -2.0), (1.0, 0.0, -1.0))
_SOBEL_Y_XCORR = ((1.0, 2.0, 1.0), (0.0, 0.0, 0.0), (-1.0, -2.0, -1.0))
_BOX = ((1.0, 1.0, 1.0), (1.0, 1.0, 1.0), (1.0, 1.0, 1.0))


def _harris_kernel(
    top_ref, mid_ref, bot_ref, o_ref, *, rows: int, steps_r: int, nblk_r: int, k: float
):
    gi = pl.program_id(0)
    ri, li = gi // steps_r, gi % steps_r
    rb = clamped_index(ri, li, steps_r, nblk_r)

    y = mid_ref.shape[1]
    top2 = top_ref[6:8, :]
    bot2 = bot_ref[0:2, :]
    # zero the halo at the image boundary (clamped neighbour = wrong rows)
    top2 = jnp.where(rb == 0, jnp.zeros_like(top2), top2)
    bot2 = jnp.where(rb == nblk_r - 1, jnp.zeros_like(bot2), bot2)

    band = jnp.concatenate([top2, mid_ref[...], bot2], axis=0)  # (rows+4, y)
    band = jnp.pad(band, ((0, 0), (2, 2)))                      # (rows+4, y+4)

    ix = _shift_conv3(band, _SOBEL_X_XCORR)   # (rows+2, y+2)
    iy = _shift_conv3(band, _SOBEL_Y_XCORR)
    sxx = _shift_conv3(ix * ix, _BOX)         # (rows, y)
    syy = _shift_conv3(iy * iy, _BOX)
    sxy = _shift_conv3(ix * iy, _BOX)
    det = sxx * syy - sxy * sxy
    trace = sxx + syy
    o_ref[...] = det - k * trace * trace


def harris_pallas(img: jnp.ndarray, g: KernelGeometry, k: float = HARRIS_K) -> jnp.ndarray:
    x, y = img.shape
    rows = g.rows_step
    if x % rows:
        raise ValueError(f"harris_pallas: rows {x} must divide rows_step {rows} (ops.py pads)")
    if rows % 8:
        raise ValueError("rows_step must be a multiple of 8")
    steps_r, nblk_r = split_grid(x, rows, g.wx)
    sub = rows // 8           # 8-row slabs per band
    nslab = x // 8

    def mid_idx(gi):
        ri, li = gi // steps_r, gi % steps_r
        return (clamped_index(ri, li, steps_r, nblk_r), 0)

    def top_idx(gi):
        rb = mid_idx(gi)[0]
        return (jnp.maximum(rb * sub - 1, 0), 0)

    def bot_idx(gi):
        rb = mid_idx(gi)[0]
        return (jnp.minimum((rb + 1) * sub, nslab - 1), 0)

    return pl.pallas_call(
        lambda t, m, b, o: _harris_kernel(
            t, m, b, o, rows=rows, steps_r=steps_r, nblk_r=nblk_r, k=k
        ),
        grid=(g.wx * steps_r,),
        in_specs=[
            pl.BlockSpec((8, y), top_idx),
            pl.BlockSpec((rows, y), mid_idx),
            pl.BlockSpec((8, y), bot_idx),
        ],
        out_specs=pl.BlockSpec((rows, y), mid_idx),
        out_shape=jax.ShapeDtypeStruct(img.shape, img.dtype),
        interpret=use_interpret(),
    )(img, img, img)
