"""Pure-jnp oracle for Harris corner detection (paper section V.D).

Pipeline: 3x3 Sobel gradients -> structure-tensor products -> 3x3 box
filter -> Harris response R = det(M) - k * trace(M)^2.  Boundary semantics:
the image is zero-extended by the total stencil radius (2) once, and both
convolution stages are 'valid' — i.e. gradients are also computed on the
zero-extension ring (the natural formulation for a fused band kernel).
Implemented with lax.conv_general_dilated so the oracle shares no code with
the Pallas kernel's shift-and-add formulation.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

HARRIS_K = 0.04

SOBEL_X = jnp.array([[-1.0, 0.0, 1.0], [-2.0, 0.0, 2.0], [-1.0, 0.0, 1.0]])
SOBEL_Y = SOBEL_X.T
BOX = jnp.ones((3, 3))


def _conv3_valid(img: jnp.ndarray, kern: jnp.ndarray) -> jnp.ndarray:
    out = lax.conv_general_dilated(
        img[None, None],
        kern[None, None].astype(img.dtype),
        window_strides=(1, 1),
        padding="VALID",
    )
    return out[0, 0]


def harris_ref(img: jnp.ndarray, k: float = HARRIS_K) -> jnp.ndarray:
    padded = jnp.pad(img, 2)
    ix = _conv3_valid(padded, SOBEL_X)   # (x+2, y+2)
    iy = _conv3_valid(padded, SOBEL_Y)
    sxx = _conv3_valid(ix * ix, BOX)     # (x, y)
    syy = _conv3_valid(iy * iy, BOX)
    sxy = _conv3_valid(ix * iy, BOX)
    det = sxx * syy - sxy * sxy
    trace = sxx + syy
    return det - k * trace * trace
