"""Pure-jnp oracle for the Add benchmark (paper section V.D: 'a simple
vector addition with two vectors of size X' — ImageCL treats them as 2-D
images, as do we)."""

import jax.numpy as jnp


def add_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a + b
