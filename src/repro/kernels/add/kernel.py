"""Tunable elementwise-add Pallas TPU kernel.

Memory-bound: the tunables set the HBM->VMEM streaming geometry.
Block = (bm * t_z, bn); the kernel body walks t_z row sub-tiles (the
'thread coarsening' analogue — one grid step amortizes pipeline overhead
over t_z tiles).  Region splits (w_x, w_y) reorder the grid traversal with
clamped indices (see kernels/common.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import KernelGeometry, clamped_index, split_grid, use_interpret


def _add_kernel(a_ref, b_ref, o_ref, *, bm: int, tz: int):
    def body(i, _):
        sl = pl.ds(i * bm, bm)
        o_ref[sl, :] = a_ref[sl, :] + b_ref[sl, :]
        return ()

    jax.lax.fori_loop(0, tz, body, ())


def add_pallas(a: jnp.ndarray, b: jnp.ndarray, g: KernelGeometry) -> jnp.ndarray:
    x, y = a.shape
    rows = g.rows_step
    steps_r, nblk_r = split_grid(x, rows, g.wx)
    steps_c, nblk_c = split_grid(y, g.bn, g.wy)

    def idx(gi, gj):
        ri, li = gi // steps_r, gi % steps_r
        rj, lj = gj // steps_c, gj % steps_c
        return (
            clamped_index(ri, li, steps_r, nblk_r),
            clamped_index(rj, lj, steps_c, nblk_c),
        )

    spec = pl.BlockSpec((rows, g.bn), idx)
    return pl.pallas_call(
        lambda a_ref, b_ref, o_ref: _add_kernel(a_ref, b_ref, o_ref, bm=g.bm, tz=g.tz),
        grid=(g.wx * steps_r, g.wy * steps_c),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=use_interpret(),
    )(a, b)
