"""Jitted public wrapper for the tunable add kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..common import Config, KernelBenchSpec, geometry_from_config
from .kernel import add_pallas


@partial(jax.jit, static_argnames=("t_x", "t_y", "t_z", "w_x", "w_y", "w_z"))
def _add(a, b, *, t_x=1, t_y=1, t_z=1, w_x=1, w_y=1, w_z=1):
    g = geometry_from_config(
        dict(t_x=t_x, t_y=t_y, t_z=t_z, w_x=w_x, w_y=w_y, w_z=w_z)
    )
    return add_pallas(a, b, g)


def add(a: jnp.ndarray, b: jnp.ndarray, config: Config | None = None) -> jnp.ndarray:
    """Tunable-config elementwise add: config holds the paper's 6 params."""
    cfg = config or {}
    return _add(
        a,
        b,
        t_x=cfg.get("t_x", 1),
        t_y=cfg.get("t_y", 1),
        t_z=cfg.get("t_z", 1),
        w_x=cfg.get("w_x", 1),
        w_y=cfg.get("w_y", 1),
        w_z=cfg.get("w_z", 1),
    )


def _bench_inputs(x: int, y: int, seed: int) -> tuple:
    rng = np.random.default_rng(seed)
    return (
        jnp.asarray(rng.standard_normal((x, y)), jnp.float32),
        jnp.asarray(rng.standard_normal((x, y)), jnp.float32),
    )


#: resource + input model for the real-measurement backend (pallas_bench)
BENCH = KernelBenchSpec(
    name="add",
    n_inputs=2,
    make_inputs=_bench_inputs,
    run=lambda inputs, cfg, x, y: add(inputs[0], inputs[1], cfg),
)
