"""Shared helpers for the tunable Pallas TPU kernels.

Kernel geometry mirrors the cost model (repro.costmodel.kernel_cost):

    bm = 8 * t_x          block rows
    bn = 128 * t_y        block cols
    t_z                   row coarsening (row-tiles per grid step)
    w_x, w_y              region splits (grid decomposition)
    w_z                   pipeline depth — on real TPU the Pallas/Mosaic
                          pipeliner owns buffer counts, so w_z only enters
                          the cost model (documented in DESIGN.md 2.1)

Region splits use *clamped block indices*: the grid is
(w_x * steps_r, w_y * steps_c) where steps cover ceil-divided padded
regions; indices past the edge clamp to the last block, which makes the
duplicated writes idempotent and keeps every (config x shape) combination
legal — matching the cost model's padding-waste semantics.

On CPU (this container) kernels run with ``interpret=True``; on a real TPU
backend the same pallas_call lowers to Mosaic.
"""

from __future__ import annotations

from dataclasses import dataclass
from math import ceil

import jax

Config = dict


@dataclass(frozen=True)
class KernelGeometry:
    bm: int
    bn: int
    tz: int
    wx: int
    wy: int
    wz: int

    @property
    def rows_step(self) -> int:
        return self.bm * self.tz


def geometry_from_config(cfg: Config) -> KernelGeometry:
    return KernelGeometry(
        bm=8 * cfg.get("t_x", 1),
        bn=128 * cfg.get("t_y", 1),
        tz=cfg.get("t_z", 1),
        wx=cfg.get("w_x", 1),
        wy=cfg.get("w_y", 1),
        wz=cfg.get("w_z", 1),
    )


def split_grid(extent: int, block: int, splits: int) -> tuple[int, int]:
    """(steps_per_region, n_blocks_total) for a clamped region split."""
    region = ceil(extent / splits)
    steps = ceil(region / block)
    n_blocks = ceil(extent / block)
    return steps, n_blocks


def clamped_index(region: int, local: int, steps: int, n_blocks: int) -> int:
    """Block index for (region, local step), clamped to the last real block.

    Written with jnp maximum/minimum so it traces inside index_maps.
    """
    import jax.numpy as jnp

    return jnp.minimum(region * steps + local, n_blocks - 1)


def use_interpret() -> bool:
    """Pallas interpret mode on CPU; compiled Mosaic on TPU."""
    return jax.default_backend() != "tpu"
