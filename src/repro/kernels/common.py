"""Shared helpers for the tunable Pallas TPU kernels.

Kernel geometry mirrors the cost model (repro.costmodel.kernel_cost):

    bm = 8 * t_x          block rows
    bn = 128 * t_y        block cols
    t_z                   row coarsening (row-tiles per grid step)
    w_x, w_y              region splits (grid decomposition)
    w_z                   pipeline depth — on real TPU the Pallas/Mosaic
                          pipeliner owns buffer counts, so w_z only enters
                          the cost model (documented in DESIGN.md 2.1)

Region splits use *clamped block indices*: the grid is
(w_x * steps_r, w_y * steps_c) where steps cover ceil-divided padded
regions; indices past the edge clamp to the last block, which makes the
duplicated writes idempotent and keeps every (config x shape) combination
legal — matching the cost model's padding-waste semantics.

On CPU (this container) kernels run with ``interpret=True``; on a real TPU
backend the same pallas_call lowers to Mosaic.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from math import ceil
from typing import Callable

import jax

Config = dict


@dataclass(frozen=True)
class KernelGeometry:
    bm: int
    bn: int
    tz: int
    wx: int
    wy: int
    wz: int

    @property
    def rows_step(self) -> int:
        return self.bm * self.tz


@dataclass(frozen=True)
class KernelBenchSpec:
    """What a kernel package publishes to the real-measurement backend
    (:mod:`repro.pallas_bench`): its per-block resource model (same fields as
    ``costmodel.KernelWorkload``, so the validity pre-screen and the
    analytical model agree on VMEM footprints) plus the two callables the
    bench harness needs — deterministic input materialization and the jitted
    entry point.

    ``make_inputs(x, y, seed)`` must be a pure function of its arguments so
    shard workers rebuild bit-identical problems from a JSON spec alone.
    ``run(inputs, cfg, x, y)`` returns the (possibly still in-flight) device
    array; the harness owns fencing and timing.  ``wz_in_program`` records
    whether ``w_z`` changes the compiled program — today the Pallas/Mosaic
    pipeliner owns buffer counts (see module docstring), so configs differing
    only in ``w_z`` share one compilation-cache entry.
    """

    name: str
    n_inputs: int
    make_inputs: Callable[[int, int, int], tuple] = field(repr=False, default=None)
    run: Callable[..., object] = field(repr=False, default=None)
    n_outputs: int = 1
    halo: int = 0
    scratch_tiles: int = 0
    bpe: int = 4
    wz_in_program: bool = False


def geometry_from_config(cfg: Config) -> KernelGeometry:
    return KernelGeometry(
        bm=8 * cfg.get("t_x", 1),
        bn=128 * cfg.get("t_y", 1),
        tz=cfg.get("t_z", 1),
        wx=cfg.get("w_x", 1),
        wy=cfg.get("w_y", 1),
        wz=cfg.get("w_z", 1),
    )


def split_grid(extent: int, block: int, splits: int) -> tuple[int, int]:
    """(steps_per_region, n_blocks_total) for a clamped region split."""
    region = ceil(extent / splits)
    steps = ceil(region / block)
    n_blocks = ceil(extent / block)
    return steps, n_blocks


def clamped_index(region: int, local: int, steps: int, n_blocks: int) -> int:
    """Block index for (region, local step), clamped to the last real block.

    Written with jnp maximum/minimum so it traces inside index_maps.
    """
    import jax.numpy as jnp

    return jnp.minimum(region * steps + local, n_blocks - 1)


def use_interpret() -> bool:
    """Pallas interpret mode on CPU; compiled Mosaic on TPU."""
    return jax.default_backend() != "tpu"
