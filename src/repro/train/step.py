"""Training and serving step functions.

``make_train_step`` builds a pjit-able (state, batch) -> (state, metrics)
with:
  * bf16 compute / fp32 master params & optimizer state,
  * selectable remat policy ("none" | "dots" | "full") applied to the
    scanned layer block,
  * gradient accumulation over ``accum`` microbatches (lax.scan) with a
    single optimizer update — one gradient all-reduce per step, not per
    microbatch (collective hygiene, DESIGN.md section 5),
  * MoE auxiliary load-balancing loss.

``make_prefill_step`` / ``make_decode_step`` are the serving entry points
(forward logits only / one token against a KV cache).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..optim.adamw import AdamWConfig, apply_updates, init_state


@dataclass(frozen=True)
class TrainSettings:
    remat: str = "dots"
    accum: int = 1               # gradient-accumulation microbatches
    aux_weight: float = 0.01     # MoE load-balance loss weight
    optimizer: AdamWConfig = AdamWConfig()
    #: cast fp32 master params to bf16 ONCE before the layer scan, so the
    #: per-layer FSDP all-gathers move bf16 instead of fp32 (EXPERIMENTS.md
    #: §Perf H1 — halves weight-gather traffic).  Off by default: the
    #: baseline casts inside each layer, which is what naive implementations
    #: do and what the paper-faithful baseline measures.
    cast_bf16: bool = False


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean token NLL in fp32.  logits (B, S, V), labels (B, S) int32."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    picked = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1
    )[..., 0]
    return (lse - picked).mean()


def _cast_tree_bf16(params):
    return jax.tree_util.tree_map(
        lambda p: p.astype(jnp.bfloat16) if p.dtype == jnp.float32 else p, params
    )


def make_loss_fn(model, settings: TrainSettings):
    def loss_fn(params, batch):
        if settings.cast_bf16:
            params = _cast_tree_bf16(params)
        if isinstance(batch, dict) and "src_embeds" in batch:   # enc-dec
            logits, aux = model.forward(params, batch, remat=settings.remat)
            labels = batch["dec_labels"]
        else:
            logits, aux = model.forward(
                params, batch["tokens"], remat=settings.remat
            )
            labels = batch["labels"]
        loss = cross_entropy(logits, labels)
        return loss + settings.aux_weight * aux, (loss, aux)

    return loss_fn


def init_train_state(model, params) -> dict:
    return {"params": params, "opt": init_state(params)}


def make_train_step(model, settings: TrainSettings, grad_shardings=None):
    """``grad_shardings``: optional tree of NamedShardings matching params.
    Without it XLA can leave the scan-backward gradient accumulator
    replicated (a full fp32 copy of the model per device — 27 GiB/chip on
    olmoe); constraining the cotangents to the parameter shardings pushes
    the sharding into the scan transpose."""
    loss_fn = make_loss_fn(model, settings)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def constrain_grads(grads):
        if grad_shardings is None:
            return grads
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, grads, grad_shardings
        )

    def train_step(state: dict, batch: dict):
        params = state["params"]
        if settings.accum == 1:
            (total, (loss, aux)), grads = grad_fn(params, batch)
            grads = constrain_grads(grads)
        else:
            a = settings.accum

            def micro(carry, mb):
                (t, (l, x)), g = grad_fn(params, mb)
                g = constrain_grads(g)
                grads_acc = jax.tree_util.tree_map(jnp.add, carry[0], g)
                return (grads_acc, carry[1] + l, carry[2] + x), ()

            mbs = jax.tree_util.tree_map(
                lambda t: t.reshape((a, t.shape[0] // a) + t.shape[1:]), batch
            )
            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss, aux), _ = jax.lax.scan(
                micro, (zero_g, jnp.float32(0), jnp.float32(0)), mbs
            )
            grads = jax.tree_util.tree_map(lambda g: g / a, grads)
            loss, aux = loss / a, aux / a

        new_params, new_opt, om = apply_updates(
            params, grads, state["opt"], settings.optimizer
        )
        metrics = {"loss": loss, "aux": aux, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_prefill_step(model, remat: str = "none"):
    def prefill_step(params, batch):
        if isinstance(batch, dict) and "src_embeds" in batch:
            enc_out = model.encode(params, batch["src_embeds"], remat=remat)
            return model.decode_train(params, enc_out, batch["dec_tokens"], remat=remat)
        logits, _ = model.forward(params, batch["tokens"], remat=remat)
        return logits

    return prefill_step


def make_decode_step(model):
    def decode_step(params, cache, cache_len, tokens):
        return model.decode_step(params, cache, cache_len, tokens)

    return decode_step
