from .step import (
    TrainSettings,
    cross_entropy,
    init_train_state,
    make_decode_step,
    make_loss_fn,
    make_prefill_step,
    make_train_step,
)

__all__ = [
    "TrainSettings",
    "cross_entropy",
    "init_train_state",
    "make_decode_step",
    "make_loss_fn",
    "make_prefill_step",
    "make_train_step",
]
