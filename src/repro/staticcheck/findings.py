"""Findings: what a rule reports, how it prints, how it is suppressed.

A :class:`Finding` is one violation (or advisory) at one source location.
Two output formats exist: the human ``path:line:col: RULE message`` form and
``--format github`` workflow annotations (``::error file=...``), so CI runs
annotate the offending lines in the PR diff.

Suppression is per-line and explicit: a trailing comment ::

    t0 = time.perf_counter()  # repro: allow[DET001]

silences exactly the named rules on that line.  A bare family name
(``allow[DET]``) silences the whole family — reserved for seam modules whose
entire point is to own the violation (``repro.core.clock``).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

#: ``# repro: allow[DET001]`` / ``# repro: allow[DET001, SER]``
_ALLOW_RE = re.compile(r"#\s*repro:\s*allow\[([A-Z]+[0-9]*(?:\s*,\s*[A-Z]+[0-9]*)*)\]")

SEVERITIES = ("error", "warning", "info")


@dataclass(frozen=True)
class Finding:
    """One rule violation at one source location.

    ``path``/``line`` locate it (``line=0`` for whole-artifact findings like
    spec pre-flight results); ``rule`` is the catalog id; ``severity`` drives
    the exit code — only ``"error"`` findings fail the check.
    """

    path: str
    line: int
    rule: str
    message: str
    severity: str = "error"
    col: int = field(default=0, compare=False)

    def sort_key(self) -> tuple:
        return (self.path, self.line, self.col, self.rule, self.message)


def format_finding(f: Finding, fmt: str = "text") -> str:
    if fmt == "github":
        level = {"error": "error", "warning": "warning", "info": "notice"}[f.severity]
        # '::' and newlines would terminate the annotation early
        msg = f.message.replace("\n", " ").replace("::", ":")
        if f.line > 0:
            return (
                f"::{level} file={f.path},line={f.line},"
                f"col={max(1, f.col)},title={f.rule}::{msg}"
            )
        return f"::{level} file={f.path},title={f.rule}::{msg}"
    tag = "" if f.severity == "error" else f" [{f.severity}]"
    return f"{f.path}:{f.line}:{f.col}: {f.rule}{tag} {f.message}"


def suppressions_for(source: str) -> dict[int, frozenset[str]]:
    """Map 1-indexed line number -> rule ids / family prefixes allowed there."""
    out: dict[int, frozenset[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        hits: set[str] = set()
        for m in _ALLOW_RE.finditer(line):
            hits.update(tok.strip() for tok in m.group(1).split(","))
        if hits:
            out[i] = frozenset(hits)
    return out


def is_suppressed(f: Finding, allowed: dict[int, frozenset[str]]) -> bool:
    tokens = allowed.get(f.line)
    if not tokens:
        return False
    family = f.rule.rstrip("0123456789")
    return f.rule in tokens or family in tokens


def apply_suppressions(
    findings: list[Finding], source_by_path: dict[str, str]
) -> tuple[list[Finding], int]:
    """Drop per-line-suppressed findings; returns (kept, n_suppressed)."""
    allow_by_path = {
        path: suppressions_for(src) for path, src in source_by_path.items()
    }
    kept = [
        f
        for f in findings
        if not is_suppressed(f, allow_by_path.get(f.path, {}))
    ]
    return kept, len(findings) - len(kept)
