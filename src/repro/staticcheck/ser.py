"""SER rules: everything on a serialized path must survive JSON.

A :class:`~repro.core.api.TuningSpec` is the unit of work the executor layer
ships to workers; anything inside it that cannot round-trip through JSON
silently downgrades the run (no sharding, no resume journal).  Two kinds of
checks live here:

* the AST check **SER003** — a ``lambda`` embedded in a ``*_kwargs`` dict
  literal (``searcher_kwargs={"fn": lambda ...}``) can never serialize;
  callers must register a named backend/constraint instead.
* the import-based checks **SER001** (TuningSpec JSON round-trip) and
  **SER002** (registered searcher/backend constructor defaults are
  JSON-representable on serializable paths), which run with the REG family
  in :mod:`.reg` because they need live registry objects.
"""

from __future__ import annotations

import ast

from .findings import Finding

JSONABLE = (str, int, float, bool, type(None))


def is_json_value(v: object) -> bool:
    """JSON-representability of a *default value* (tuples serialize as
    lists, which every consumer in this repo round-trips back)."""
    if isinstance(v, JSONABLE):
        return True
    if isinstance(v, (list, tuple)):
        return all(is_json_value(x) for x in v)
    if isinstance(v, dict):
        return all(
            isinstance(k, str) and is_json_value(x) for k, x in v.items()
        )
    return False


def check_file(path: str, tree: ast.AST) -> list[Finding]:
    findings: list[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            if (
                kw.arg
                and kw.arg.endswith("_kwargs")
                and isinstance(kw.value, ast.Dict)
            ):
                for key, value in zip(kw.value.keys, kw.value.values, strict=True):
                    if isinstance(value, ast.Lambda):
                        keyname = (
                            key.value
                            if isinstance(key, ast.Constant)
                            else "<dynamic>"
                        )
                        findings.append(
                            Finding(
                                path=path,
                                line=value.lineno,
                                col=value.col_offset,
                                rule="SER003",
                                message=(
                                    f"lambda in {kw.arg}[{keyname!r}] cannot "
                                    "serialize; name a registered backend/"
                                    "constraint instead"
                                ),
                            )
                        )
    return findings
