"""LIB rules: library-code hygiene.

**LIB001** — a bare ``assert`` in library code is an error-handling bug
waiting for ``python -O``: asserts compile away under optimization, so a
"call fit first" guard silently vanishes exactly when someone runs the
paper-scale matrix with ``-O`` for speed.  Runtime state errors must raise
real exceptions (``RuntimeError`` / ``ValueError``); ``assert`` is for
developer-facing invariants in tests only (which this checker never scans).
"""

from __future__ import annotations

import ast

from .findings import Finding


def check_file(path: str, tree: ast.AST) -> list[Finding]:
    return [
        Finding(
            path=path,
            line=node.lineno,
            col=node.col_offset,
            rule="LIB001",
            message=(
                "bare assert is stripped under python -O; raise "
                "RuntimeError/ValueError for runtime errors in library code"
            ),
        )
        for node in ast.walk(tree)
        if isinstance(node, ast.Assert)
    ]
