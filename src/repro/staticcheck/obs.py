"""OBS rules: telemetry must never reach run identity.

The telemetry layer's contract (docs/telemetry.md) is that tracing is a
*pure observability knob*: a telemetry-enabled run produces bit-identical
measurement stores, journals, and cache keys to a disabled one.  The
contract dies quietly if a trace setting ever flows into one of the
identity sinks — ``default_cache_key`` (the shared store namespace),
``journal_namespace`` (resume validity), ``_spec_fingerprint`` (the
analysis layer's run identity).

**OBS001** is a per-file lexical taint check over those sinks (the same
sink list PROV001 guards, plus each sink's same-file callees): any
telemetry identifier — ``telemetry`` / ``tracer`` / ``trace_path`` /
``trace_dir`` / ``trace_src`` — appearing inside a sink body as a name, an
attribute, or a string constant is an error.  Unlike PROV001 there is no
"exclusion context" escape: provenance sinks legitimately *filter* speed
knobs out of ``backend_kwargs``, but a telemetry token has no business in
an identity sink at all — not even to exclude itself, because telemetry is
a session/runtime knob that never enters the spec in the first place.
"""

from __future__ import annotations

import ast

from .findings import Finding
from .prov import SINK_NAMES, _called_names

#: identifiers that mark telemetry plumbing; substrings are NOT matched —
#: a token must be the whole name / attribute / string constant, so e.g.
#: ``backtrace`` or ``retrace`` never false-positive.  The serving layer's
#: plumbing (``serve_dir`` — where the winners index lives, ``qdir`` /
#: ``queue_dir`` — where fleet claims live) is equally identity-free: the
#: same spec tuned through any serve dir must produce the same store bytes.
TELEMETRY_TOKENS = ("telemetry", "tracer", "trace_path", "trace_dir",
                    "trace_src", "serve_dir", "qdir", "queue_dir")


def _token_mentions(fn: ast.FunctionDef) -> list[tuple[str, int]]:
    """Every (token, line) where a telemetry identifier appears in ``fn``."""
    out: list[tuple[str, int]] = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and node.id in TELEMETRY_TOKENS:
            out.append((node.id, node.lineno))
        elif isinstance(node, ast.Attribute) and node.attr in TELEMETRY_TOKENS:
            out.append((node.attr, node.lineno))
        elif (
            isinstance(node, ast.Constant)
            and isinstance(node.value, str)
            and node.value in TELEMETRY_TOKENS
        ):
            out.append((node.value, node.lineno))
        elif isinstance(node, ast.arg) and node.arg in TELEMETRY_TOKENS:
            out.append((node.arg, node.lineno))
    return out


def check_file(path: str, tree: ast.AST) -> list[Finding]:
    functions: dict[str, ast.FunctionDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            functions.setdefault(node.name, node)
    findings: list[Finding] = []
    # dedupe across sinks: a helper shared by two sinks reports once per line
    seen: set[tuple[int, str]] = set()
    for sink_name in SINK_NAMES:
        fn = functions.get(sink_name)
        if fn is None:
            continue
        # the sink plus its same-file helpers form the checked body —
        # mirroring PROV001, so a sink can't hide the leak in a callee
        bodies = [fn] + [
            functions[n]
            for n in _called_names(fn)
            if n in functions and n != sink_name
        ]
        for body in bodies:
            for token, line in _token_mentions(body):
                if (line, token) in seen:
                    continue
                seen.add((line, token))
                findings.append(
                    Finding(
                        path=path,
                        line=line,
                        rule="OBS001",
                        message=(
                            f"telemetry identifier '{token}' inside identity "
                            f"sink {sink_name}() — telemetry is observability "
                            "only and must never feed cache keys, journal "
                            "namespaces, or spec fingerprints"
                        ),
                    )
                )
    return findings
