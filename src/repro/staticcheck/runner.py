"""Orchestration: discover files, run rule families, apply suppressions.

:func:`check_paths` is the programmatic entry point (the CLI in
``__main__`` and the fixture tests both call it).  AST rule families run
per-file; PROV runs over the whole scanned set (its liveness analysis is
cross-file); the import-based registry checks run once per invocation and
can be disabled (``registry=False``) for fixture corpora that are not
importable packages.
"""

from __future__ import annotations

import ast
import os

from . import det, lib, obs, prov, ser
from .catalog import resolve_select
from .findings import Finding, apply_suppressions

SKIP_DIRS = frozenset(
    {".git", "__pycache__", ".ruff_cache", "results", "node_modules", ".venv"}
)


def iter_py_files(paths: list[str]) -> list[str]:
    """Expand files/directories into a sorted, deduplicated .py file list."""
    out: list[str] = []
    seen: set[str] = set()
    for path in paths:
        if os.path.isfile(path):
            targets = [path]
        elif os.path.isdir(path):
            targets = []
            for root, dirs, files in os.walk(path):
                dirs[:] = sorted(d for d in dirs if d not in SKIP_DIRS)
                targets.extend(
                    os.path.join(root, f) for f in sorted(files)
                    if f.endswith(".py")
                )
        else:
            raise FileNotFoundError(path)
        for t in targets:
            norm = os.path.normpath(t)
            if norm not in seen and norm.endswith(".py"):
                seen.add(norm)
                out.append(norm)
    return out


def check_paths(
    paths: list[str],
    *,
    select: str | None = None,
    registry: bool = True,
) -> list[Finding]:
    """Run the static checks over ``paths``; returns sorted findings with
    per-line suppressions already applied.

    ``select`` limits output to a comma-separated rule/family list.
    ``registry=False`` skips the import-based REG/SER checks (fixture
    corpora; syntax-only runs).
    """
    files = iter_py_files(paths)
    findings: list[Finding] = []
    sources: dict[str, str] = {}
    prov_facts = {}
    for path in files:
        try:
            with open(path, encoding="utf-8") as f:
                source = f.read()
        except OSError as e:
            findings.append(
                Finding(path, 0, "PARSE", f"unreadable: {e}")
            )
            continue
        sources[path] = source
        try:
            tree = ast.parse(source, filename=path)
        except SyntaxError as e:
            findings.append(
                Finding(path, e.lineno or 0, "PARSE", f"syntax error: {e.msg}")
            )
            continue
        findings += det.check_file(path, tree)
        findings += lib.check_file(path, tree)
        findings += ser.check_file(path, tree)
        findings += obs.check_file(path, tree)
        prov_facts[path] = prov.collect_facts(path, tree)
    findings += prov.check_project(prov_facts)
    if registry:
        from .reg import check_registries

        findings += check_registries()
    # registry findings anchor at def sites that may live outside the scanned
    # paths; load those sources too so their allow-comments are honored
    for f in findings:
        if f.path not in sources and os.path.isfile(f.path):
            try:
                with open(f.path, encoding="utf-8") as fh:
                    sources[f.path] = fh.read()
            except OSError:
                pass
    findings, _ = apply_suppressions(findings, sources)
    selected = resolve_select(select)
    if selected is not None:
        findings = [f for f in findings if f.rule in selected]
    return sorted(findings, key=Finding.sort_key)
