"""CLI: ``python -m repro.staticcheck [paths...] [options]``.

Exit code 0 when no *error*-severity findings remain after suppressions
(warnings and infos print but do not fail), 1 otherwise, 2 on usage errors.
"""

from __future__ import annotations

import argparse
import json
import sys

from .catalog import RULES, resolve_select
from .findings import format_finding
from .runner import check_paths
from .spec_rules import preflight_paper, preflight_spec


def _list_rules() -> None:
    width = max(len(r.id) for r in RULES.values())
    for rule in sorted(RULES.values(), key=lambda r: r.id):
        if rule.family == "PARSE":
            continue
        sev = "" if rule.severity == "error" else f" [{rule.severity}]"
        print(f"{rule.id:<{width}}  {rule.summary}{sev}")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.staticcheck",
        description=(
            "Static determinism/provenance/registry checks gating the "
            "paper-scale run."
        ),
    )
    ap.add_argument("paths", nargs="*", help="files or directories to check")
    ap.add_argument(
        "--format",
        choices=("text", "github"),
        default="text",
        help="finding output format (github: workflow annotations)",
    )
    ap.add_argument(
        "--select",
        default=None,
        help="comma-separated rule ids or families (e.g. DET,PROV001)",
    )
    ap.add_argument(
        "--no-registry",
        action="store_true",
        help="skip the import-based REG/SER registry checks",
    )
    ap.add_argument(
        "--preflight",
        metavar="SPEC_JSON",
        default=None,
        help="pre-flight a TuningSpec JSON file (space size, constraint "
        "satisfiability, seed namespaces)",
    )
    ap.add_argument(
        "--preflight-paper",
        action="store_true",
        help="pre-flight the paper's full 3x3 combo matrix",
    )
    ap.add_argument(
        "--list-rules", action="store_true", help="print the rule catalog"
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        _list_rules()
        return 0
    try:
        resolve_select(args.select)
    except KeyError as e:
        print(e.args[0], file=sys.stderr)
        return 2

    findings = []
    if args.paths:
        findings += check_paths(
            args.paths, select=args.select, registry=not args.no_registry
        )
    if args.preflight is not None:
        from repro.core.api import TuningSpec

        with open(args.preflight, encoding="utf-8") as f:
            spec = TuningSpec.from_dict(json.load(f))
        findings += preflight_spec(spec, where=args.preflight)
    if args.preflight_paper:
        findings += preflight_paper()
    if not args.paths and args.preflight is None and not args.preflight_paper:
        ap.print_usage(sys.stderr)
        print(
            "error: give paths to check and/or --preflight/--preflight-paper",
            file=sys.stderr,
        )
        return 2

    for f in findings:
        print(format_finding(f, args.format))
    errors = sum(1 for f in findings if f.severity == "error")
    notes = len(findings) - errors
    tail = f", {notes} advisory" if notes else ""
    print(
        f"staticcheck: {errors} error finding(s){tail}"
        if findings
        else "staticcheck: clean"
    )
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
