"""DET rules: results must not depend on when or where they were computed.

Scope: the determinism-critical modules — everything whose output feeds
measured values, seeds, caches, or serialized results.  For this repo that
is ``repro/core/`` and ``repro/pallas_bench/`` (searchers, surrogates, the
engine, work units, stores, the session driver, the measurement pipeline).
Files outside a ``repro`` package (fixtures, ad-hoc scripts passed
explicitly) are always in scope.  The analysis/launch/models layers
legitimately read wall clock (progress logs, training walls) and are out of
scope by construction, not by suppression.

* **DET001** — non-injected wall clock.  ``time.time()`` and friends inside
  critical code make timing part of the result path; the one sanctioned
  seam is :mod:`repro.core.clock` (which carries the allowlist entry).
* **DET002** — unseeded global randomness: ``np.random.<fn>()`` module-state
  draws and stdlib ``random.<fn>()``.  Constructing seeded generators
  (``default_rng``, ``Generator``, ``SeedSequence``...) is fine.
* **DET003** — iterating an unordered ``set`` where the order can feed
  downstream state, unless wrapped in ``sorted()``.  Order-insensitive
  reductions (``len``/``min``/``max``/``sum``/``any``/``all``) are exempt.
"""

from __future__ import annotations

import ast

from .catalog import RULES
from .findings import Finding

#: dotted names whose *call* is a DET001 violation
WALL_CLOCK_CALLS = frozenset(
    {
        "time.time",
        "time.time_ns",
        "time.perf_counter",
        "time.perf_counter_ns",
        "time.monotonic",
        "time.monotonic_ns",
        "time.process_time",
        "time.process_time_ns",
        "time.clock_gettime",
        "datetime.datetime.now",
        "datetime.datetime.utcnow",
        "datetime.datetime.today",
        "datetime.date.today",
    }
)

#: np.random attributes that construct *seeded* generators (allowed)
NP_RANDOM_OK = frozenset(
    {
        "default_rng",
        "Generator",
        "SeedSequence",
        "BitGenerator",
        "PCG64",
        "PCG64DXSM",
        "Philox",
        "MT19937",
        "SFC64",
    }
)

#: stdlib ``random`` module functions that draw from hidden global state
STDLIB_RANDOM_BANNED = frozenset(
    {
        "random",
        "randint",
        "randrange",
        "choice",
        "choices",
        "sample",
        "shuffle",
        "uniform",
        "gauss",
        "normalvariate",
        "betavariate",
        "expovariate",
        "seed",
        "getrandbits",
    }
)

#: calls whose result is order-insensitive — consuming a set through these
#: is deterministic
ORDER_INSENSITIVE = frozenset(
    {"len", "min", "max", "sum", "any", "all", "sorted", "frozenset", "set"}
)

#: consuming a set through these materializes its (arbitrary) order
ORDER_MATERIALIZING = frozenset({"list", "tuple", "iter", "enumerate", "zip"})

DET_CRITICAL_PARTS = ("repro/core/", "repro/pallas_bench/")


def is_det_critical(path: str) -> bool:
    p = path.replace("\\", "/")
    if "repro/" not in p:
        return True  # fixtures / explicit files: always in scope
    return any(part in p for part in DET_CRITICAL_PARTS)


class _ImportMap:
    """Resolve ``name.attr.attr`` chains back to canonical module paths."""

    def __init__(self, tree: ast.AST):
        self.alias: dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.alias[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0]
                    )
            elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
                for a in node.names:
                    self.alias[a.asname or a.name] = f"{node.module}.{a.name}"

    def dotted(self, node: ast.expr) -> str | None:
        """Canonical dotted name of a Name/Attribute chain, or None."""
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        head = self.alias.get(node.id, node.id)
        # normalize the one alias this codebase actually uses
        if head == "numpy":
            head = "np"
        parts.append(head)
        return ".".join(reversed(parts))


def walk_scope(scope: ast.AST):
    """Walk a scope's own statements without descending into nested
    function/class scopes (their names are their own)."""
    stack = list(ast.iter_child_nodes(scope))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(
            node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef, ast.Lambda)
        ):
            stack.extend(ast.iter_child_nodes(node))


def _scopes(tree: ast.AST):
    yield tree
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _ann_is_set(ann: ast.expr | None) -> bool:
    if isinstance(ann, ast.Name):
        return ann.id in ("set", "frozenset")
    if isinstance(ann, ast.Subscript) and isinstance(ann.value, ast.Name):
        return ann.value.id in ("set", "frozenset")
    return False


def _set_typed_names(scope: ast.AST) -> set[str]:
    """Names assigned (or annotated as) an obvious set value in one scope."""
    names: set[str] = set()
    if isinstance(scope, (ast.FunctionDef, ast.AsyncFunctionDef)):
        a = scope.args
        for arg in [*a.posonlyargs, *a.args, *a.kwonlyargs]:
            if _ann_is_set(arg.annotation):
                names.add(arg.arg)
    for node in walk_scope(scope):
        value = None
        targets: list[ast.expr] = []
        if isinstance(node, ast.Assign):
            value, targets = node.value, node.targets
        elif isinstance(node, ast.AnnAssign) and node.target is not None:
            targets = [node.target]
            value = node.value
            ann = node.annotation
            ann_name = (
                ann.id
                if isinstance(ann, ast.Name)
                else ann.value.id
                if isinstance(ann, ast.Subscript) and isinstance(ann.value, ast.Name)
                else None
            )
            if ann_name in ("set", "frozenset"):
                for t in targets:
                    if isinstance(t, ast.Name):
                        names.add(t.id)
        if value is not None and _is_set_expr(value, names):
            for t in targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
    return names


def _is_set_expr(node: ast.expr, set_names: set[str]) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Name)
        and node.func.id in ("set", "frozenset")
    ):
        return True
    if isinstance(node, ast.Name) and node.id in set_names:
        return True
    if isinstance(node, ast.BinOp) and isinstance(
        node.op, (ast.BitAnd, ast.BitOr, ast.BitXor, ast.Sub)
    ):
        # set algebra: a & b, keys_a - keys_b ... set-ness propagates
        return _is_set_expr(node.left, set_names) or _is_set_expr(
            node.right, set_names
        )
    return False


def check_file(path: str, tree: ast.AST) -> list[Finding]:
    findings: list[Finding] = []
    if not is_det_critical(path):
        return findings
    imap = _ImportMap(tree)

    def f(rule: str, node: ast.AST, msg: str) -> None:
        findings.append(
            Finding(
                path=path,
                line=getattr(node, "lineno", 0),
                col=getattr(node, "col_offset", 0),
                rule=rule,
                message=msg,
                severity=RULES[rule].severity,
            )
        )

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            name = imap.dotted(node.func)
            if name is None:
                continue
            if name in WALL_CLOCK_CALLS:  # noqa: SIM114 — distinct messages
                f(
                    "DET001",
                    node,
                    f"non-injected wall clock {name}() in determinism-"
                    "critical code; use repro.core.clock.monotonic()",
                )
            elif name.startswith("np.random."):
                attr = name.split(".", 2)[2]
                if "." not in attr and attr not in NP_RANDOM_OK:
                    f(
                        "DET002",
                        node,
                        f"np.random.{attr}() draws from unseeded global "
                        "state; use np.random.default_rng(seed)",
                    )
            elif name.startswith("random."):
                attr = name.split(".", 1)[1]
                if attr in STDLIB_RANDOM_BANNED:
                    f(
                        "DET002",
                        node,
                        f"stdlib random.{attr}() draws from unseeded global "
                        "state; use np.random.default_rng(seed)",
                    )
    # DET003 is scope-local: set-ness of a name is judged per function
    for scope in _scopes(tree):
        set_names = _set_typed_names(scope)
        for node in walk_scope(scope):
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id in ORDER_MATERIALIZING
            ):
                for arg in node.args:
                    if _is_set_expr(arg, set_names):
                        f(
                            "DET003",
                            arg,
                            f"{node.func.id}() materializes unordered set "
                            "iteration order; wrap the set in sorted()",
                        )
            elif isinstance(node, ast.For):
                if _is_set_expr(node.iter, set_names):
                    f(
                        "DET003",
                        node.iter,
                        "for-loop over an unordered set; wrap in sorted() "
                        "if iteration order can feed results or serialized "
                        "output",
                    )
            elif isinstance(node, (ast.ListComp, ast.GeneratorExp, ast.DictComp)):
                for comp in node.generators:
                    if _is_set_expr(comp.iter, set_names):
                        f(
                            "DET003",
                            comp.iter,
                            "comprehension over an unordered set; wrap in "
                            "sorted() if order can feed results",
                        )
    return findings
