"""PROV rules: speed knobs must never reach provenance namespaces.

The executor layer's core promise is that *how fast* a run executes never
changes *what* it computes: ``pipeline_workers``, ``max_workers``,
``executor``, ``futures_pool``, ``scheduler``, ``compile_cache`` may change
wall-clock only.  The promise is
load-bearing in three sink functions — ``default_cache_key`` (the shared
measurement-store namespace), ``journal_namespace`` (resume validity), and
``_spec_fingerprint`` (the analysis layer's run identity).  If a knob leaks
into any of them, warm caches stop matching across worker counts and resume
journals orphan themselves whenever someone changes parallelism.

**PROV001** is a lightweight cross-file taint check over the scanned set:

1. *Liveness*: a knob is **live** if any scanned file injects it into
   ``backend_kwargs`` — a dict literal containing the knob as a key that is
   either bound to a ``backend_kwargs=`` keyword/assignment or spreads
   ``**...backend_kwargs``, or a ``...backend_kwargs[...] [knob] = ...``
   subscript store.
2. *Sink obligation*: every sink function (by name, plus its same-file
   callees) that reads ``backend_kwargs`` must **exclude** each live knob —
   mention it in an exclusion context: a comparison (``k != "knob"``,
   ``k not in (...)``) or a ``.pop("knob", ...)``.

Deleting the one-line filter in ``TuningSpec.default_cache_key`` makes this
rule fire — that regression is pinned by the fixture corpus.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field

from .findings import Finding

SPEED_KNOBS = (
    "pipeline_workers",
    "max_workers",
    "executor",
    "futures_pool",
    "scheduler",
    "compile_cache",
    # fleet pacing (repro.serving): how fast a queue drains, never what the
    # tuned values are — byte-identity of fleet vs serial runs depends on it
    "claim_timeout_s",
    "poll_s",
    "stall_s",
)

SINK_NAMES = ("default_cache_key", "journal_namespace", "_spec_fingerprint")

_KWARGS_MARKER = "backend_kwargs"


@dataclass
class _FileFacts:
    path: str
    #: knob -> first injection line
    injections: dict[str, int] = field(default_factory=dict)
    #: function name -> FunctionDef node (module + class level)
    functions: dict[str, ast.FunctionDef] = field(default_factory=dict)


def _mentions_kwargs(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr == _KWARGS_MARKER:
            return True
        if isinstance(sub, ast.Name) and sub.id == _KWARGS_MARKER:
            return True
        if (
            isinstance(sub, ast.Constant)
            and isinstance(sub.value, str)
            and sub.value == _KWARGS_MARKER
        ):
            return True
    return False


def _dict_knob_keys(d: ast.Dict) -> list[tuple[str, int]]:
    out = []
    for k in d.keys:
        if (
            k is not None
            and isinstance(k, ast.Constant)
            and isinstance(k.value, str)
            and k.value in SPEED_KNOBS
        ):
            out.append((k.value, k.lineno))
    return out


def _dict_spreads_kwargs(d: ast.Dict) -> bool:
    return any(
        k is None and _mentions_kwargs(v) for k, v in zip(d.keys, d.values, strict=True)
    )


def collect_facts(path: str, tree: ast.AST) -> _FileFacts:
    facts = _FileFacts(path=path)
    # dict literals bound to a backend_kwargs keyword / assignment target
    bound_dicts: set[int] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg == _KWARGS_MARKER and isinstance(kw.value, ast.Dict):
                    bound_dicts.add(id(kw.value))
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if (
                    isinstance(t, (ast.Name, ast.Attribute))
                    and _mentions_kwargs(t)
                    and isinstance(node.value, ast.Dict)
                ):
                    bound_dicts.add(id(node.value))
            # spec.backend_kwargs["pipeline_workers"] = N
            for t in node.targets:
                if (
                    isinstance(t, ast.Subscript)
                    and _mentions_kwargs(t.value)
                    and isinstance(t.slice, ast.Constant)
                    and t.slice.value in SPEED_KNOBS
                ):
                    facts.injections.setdefault(t.slice.value, t.lineno)
    for node in ast.walk(tree):
        if isinstance(node, ast.Dict):
            knobs = _dict_knob_keys(node)
            if not knobs:
                continue
            if id(node) in bound_dicts or _dict_spreads_kwargs(node):
                for knob, line in knobs:
                    facts.injections.setdefault(knob, line)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            facts.functions.setdefault(node.name, node)
    return facts


def _called_names(fn: ast.FunctionDef) -> set[str]:
    out: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Name):
                out.add(node.func.id)
            elif isinstance(node.func, ast.Attribute):
                out.add(node.func.attr)
    return out


def _excludes_knob(fn_nodes: list[ast.FunctionDef], knob: str) -> bool:
    """True if any of the sink's bodies mentions ``knob`` in an exclusion
    context: inside a comparison, or as the key argument of ``.pop``/
    ``.discard``/``del``."""
    for fn in fn_nodes:
        for node in ast.walk(fn):
            if isinstance(node, ast.Compare):
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Constant) and sub.value == knob:
                        return True
            elif isinstance(node, ast.Call) and isinstance(
                node.func, ast.Attribute
            ):
                if node.func.attr in ("pop", "discard") and node.args:
                    a0 = node.args[0]
                    if isinstance(a0, ast.Constant) and a0.value == knob:
                        return True
            elif isinstance(node, ast.Delete):
                for t in node.targets:
                    if (
                        isinstance(t, ast.Subscript)
                        and isinstance(t.slice, ast.Constant)
                        and t.slice.value == knob
                    ):
                        return True
    return False


def check_project(facts_by_path: dict[str, _FileFacts]) -> list[Finding]:
    live: dict[str, tuple[str, int]] = {}
    for facts in facts_by_path.values():
        for knob, line in facts.injections.items():
            live.setdefault(knob, (facts.path, line))
    if not live:
        return []
    findings: list[Finding] = []
    for facts in facts_by_path.values():
        for sink_name in SINK_NAMES:
            fn = facts.functions.get(sink_name)
            if fn is None:
                continue
            # the sink plus its same-file helpers form the checked body
            bodies = [fn] + [
                facts.functions[n]
                for n in _called_names(fn)
                if n in facts.functions and n != sink_name
            ]
            if not any(_mentions_kwargs(b) for b in bodies):
                continue
            for knob, (inj_path, inj_line) in sorted(live.items()):
                if not _excludes_knob(bodies, knob):
                    findings.append(
                        Finding(
                            path=facts.path,
                            line=fn.lineno,
                            col=fn.col_offset,
                            rule="PROV001",
                            message=(
                                f"speed knob '{knob}' is injected into "
                                f"backend_kwargs ({inj_path}:{inj_line}) but "
                                f"{sink_name}() does not exclude it — the "
                                "knob would leak into cache/journal "
                                "namespaces"
                            ),
                        )
                    )
    return findings
