"""``repro.staticcheck`` — the static gate on the paper-scale run.

Every claim table this repro emits rests on invariants the test suite can
only establish by *re-running* things: bit-identical results across
executors, speed knobs excluded from cache keys, JSON-round-trippable specs,
complete registries.  This package checks those invariants **statically, in
seconds** — before any compile, any measurement, any multi-million-sample
matrix:

* :mod:`.det`   — DET rules: no non-injected wall clock, no unseeded global
  randomness, no unordered-set iteration in determinism-critical modules.
* :mod:`.prov`  — PROV rules: speed knobs (``pipeline_workers`` & friends)
  provably never reach cache keys, journal namespaces, spec fingerprints.
* :mod:`.reg`   — REG rules: the SEARCHERS / BACKENDS / EXECUTORS / STORES /
  KERNEL_BENCHES registries are complete and constructible.
* :mod:`.ser`   — SER rules: specs and registered kwargs stay
  JSON-representable; no callables sneak into serialized paths.
* :mod:`.lib`   — LIB rules: no bare ``assert`` for runtime errors in
  library code (stripped under ``python -O``).
* :mod:`.spec_rules` — the spec-level pre-flight: space size, unsatisfiable
  constraints, seed-namespace collisions for a :class:`TuningSpec` or the
  full paper design.

Run it::

    python -m repro.staticcheck src            # lint the package tree
    python -m repro.staticcheck --preflight-paper
    python -m repro.staticcheck --list-rules

Findings carry rule ids and ``file:line``; ``--format github`` emits CI
annotations; a trailing ``# repro: allow[RULE]`` comment suppresses a rule
(or a whole family: ``# repro: allow[DET]``) on that line.
"""

from __future__ import annotations

from .catalog import RULES, Rule
from .findings import Finding, format_finding, suppressions_for
from .runner import check_paths
from .spec_rules import preflight_design, preflight_paper, preflight_spec

__all__ = [
    "RULES",
    "Finding",
    "Rule",
    "check_paths",
    "format_finding",
    "preflight_design",
    "preflight_paper",
    "preflight_spec",
    "suppressions_for",
]
