"""The spec-level pre-flight: catch doomed runs before any compile.

A paper-exact matrix is ~3M samples; a spec with an unsatisfiable
constraint, a colliding seed namespace, or no persistent store wastes hours
before anyone notices.  Given a :class:`~repro.core.api.TuningSpec` (or the
full paper design), these checks statically resolve the search space and the
experiment plan and report:

* **SPEC001** (info) — resolved space size and the constrained fraction
  (exact enumeration up to 2^16 configs, a seeded 4096-point Monte-Carlo
  estimate above).
* **SPEC002** — the constrained space is empty/unsatisfiable: every search
  would die in rejection sampling.
* **SPEC003** — experiment-seed namespace collisions: two (algo, S, e)
  cells hashing to the same ``stable_seed`` would silently share cached
  measurements under one cache key.
* **SPEC004** (warning) — a paper-scale design (>= 250k search samples)
  with no persistent store: a crash at hour N re-measures everything.
* **SPEC005** (info) — design rows below ``analysis.claims.MIN_EXPERIMENTS``
  leave the paper-claim verdicts undecidable.

``preflight_paper()`` runs the whole battery over the paper's
3-benchmark x 3-chip combo specs — the CI gate on the payoff run.
"""

from __future__ import annotations

from collections import defaultdict

import numpy as np

from .catalog import RULES
from .findings import Finding

#: exact constraint enumeration below this many configs; Monte-Carlo above
EXACT_ENUMERATION_LIMIT = 2**16
MC_SAMPLES = 4096
#: one paper combo is 5 algos x 100k search samples; anything in that class
#: (>= 250k) deserves a persistent store
PAPER_SCALE_SAMPLES = 250_000


def _finding(rule: str, where: str, message: str) -> Finding:
    return Finding(
        path=where,
        line=0,
        rule=rule,
        message=message,
        severity=RULES[rule].severity,
    )


def _resolve_space(spec):
    """The space the session would search, without building a measurement."""
    from repro.core.backends import BACKENDS

    if spec.space is not None:
        return spec.space
    backend = BACKENDS[spec.backend]
    if backend.default_space is None:
        return None
    return backend.default_space(kernel=spec.kernel, **spec.backend_kwargs)


def constrained_fraction(space) -> float:
    """Fraction of the raw space satisfying the constraint (exact when the
    space is small, seeded Monte-Carlo when it is not)."""
    if space.constraint is None:
        return 1.0
    total = space.cardinality
    if total <= EXACT_ENUMERATION_LIMIT:
        idxs = np.stack(
            np.meshgrid(
                *[np.arange(c) for c in space.cardinalities], indexing="ij"
            ),
            axis=-1,
        ).reshape(-1, space.n_params)
        return float(space.valid_mask(idxs).mean())
    rng = np.random.default_rng(0)
    raw = space.unconstrained().sample_indices(rng, MC_SAMPLES)
    return float(space.valid_mask(raw).mean())


def check_space(spec, where: str = "<spec>") -> list[Finding]:
    findings: list[Finding] = []
    try:
        space = _resolve_space(spec)
    except Exception as e:  # noqa: BLE001 — unresolvable space IS the finding
        return [
            _finding(
                "SPEC002",
                where,
                f"search space failed to resolve: {type(e).__name__}: {e}",
            )
        ]
    if space is None:
        return [
            _finding(
                "SPEC002",
                where,
                f"backend {spec.backend!r} has no default space and the "
                "spec sets none — the session would refuse to start",
            )
        ]
    total = space.cardinality
    frac = constrained_fraction(space)
    exact = total <= EXACT_ENUMERATION_LIMIT or space.constraint is None
    findings.append(
        _finding(
            "SPEC001",
            where,
            f"space: {total:,} configs across {space.n_params} params; "
            f"constrained fraction {'=' if exact else '~'}{frac:.1%}",
        )
    )
    if total == 0 or frac == 0.0:
        findings.append(
            _finding(
                "SPEC002",
                where,
                "the constrained space admits no configuration — every "
                "search would fail rejection sampling"
                + ("" if exact else f" (0/{MC_SAMPLES} MC samples valid)"),
            )
        )
    return findings


def check_seed_namespace(spec, where: str = "<spec>") -> list[Finding]:
    """Collisions in the DiskCachedMeasurement ``seed=`` namespace."""
    from repro.core.runner import stable_seed

    if spec.design is None:
        return []
    seen: dict[int, tuple] = {}
    collisions: list[tuple] = []
    for algo in spec.matrix_algorithms:
        for s, e_total in spec.design.rows():
            for e in range(e_total):
                seed = stable_seed(spec.seed, algo, s, e)
                cell = (algo, s, e)
                if seed in seen and seen[seed] != cell:
                    collisions.append((seen[seed], cell, seed))
                else:
                    seen[seed] = cell
    findings = []
    for first, second, seed in collisions[:5]:
        findings.append(
            _finding(
                "SPEC003",
                where,
                f"experiment-seed collision: cells {first} and {second} "
                f"both hash to seed {seed} — they would share cached "
                "measurements under one cache key",
            )
        )
    if len(collisions) > 5:
        findings.append(
            _finding(
                "SPEC003",
                where,
                f"... {len(collisions) - 5} more seed collisions",
            )
        )
    return findings


def check_scale(spec, where: str = "<spec>") -> list[Finding]:
    if spec.design is None:
        return []
    findings: list[Finding] = []
    n_algos = len(spec.matrix_algorithms)
    total = spec.design.total_search_samples * n_algos
    if total >= PAPER_SCALE_SAMPLES and spec.store is None:
        findings.append(
            _finding(
                "SPEC004",
                where,
                f"paper-scale design ({total:,} search samples) without a "
                "persistent store: a crash re-measures everything — set "
                "TuningSpec.store='sqlite'",
            )
        )
    try:
        from repro.analysis.claims import MIN_EXPERIMENTS
    except Exception:  # noqa: BLE001 — analysis layer optional here
        MIN_EXPERIMENTS = 20
    thin = [(s, e) for s, e in spec.design.rows() if e < MIN_EXPERIMENTS]
    if thin:
        findings.append(
            _finding(
                "SPEC005",
                where,
                f"{len(thin)} design row(s) have fewer than "
                f"{MIN_EXPERIMENTS} experiments (e.g. S={thin[0][0]}, "
                f"E={thin[0][1]}): paper-claim verdicts stay undecidable",
            )
        )
    return findings


def check_cache_key_namespaces(specs, where: str = "<specs>") -> list[Finding]:
    """Distinct specs sharing one store must not share a cache key."""
    by_key: dict[str, list] = defaultdict(list)
    for spec in specs:
        if spec.store is None:
            continue
        key = (spec.store, spec.store_path, spec.cache_key or spec.default_cache_key())
        by_key[key].append(spec)
    findings = []
    for (_, path, cache_key), group in sorted(by_key.items(), key=str):
        if len(group) < 2:
            continue
        dicts = []
        for s in group:
            d = s.to_dict()
            d.pop("store", None), d.pop("store_path", None)
            dicts.append(d)
        if any(d != dicts[0] for d in dicts[1:]):
            findings.append(
                _finding(
                    "SPEC003",
                    where,
                    f"{len(group)} distinct specs share cache key "
                    f"{cache_key!r} in store {path!r}: cached measurements "
                    "would cross-serve between different problems",
                )
            )
    return findings


def preflight_spec(spec, where: str = "<spec>") -> list[Finding]:
    """The full battery for one spec."""
    findings = check_space(spec, where)
    if any(f.rule == "SPEC002" for f in findings):
        return findings  # the space is broken; the rest would only cascade
    findings += check_seed_namespace(spec, where)
    findings += check_scale(spec, where)
    return findings


def preflight_design(design, seed: int = 0, algorithms=("rs", "ga"),
                     where: str = "<design>") -> list[Finding]:
    """Design-only battery (no backend): seeds + scale, space skipped."""
    from repro.core.api import TuningSpec

    spec = TuningSpec(
        kernel="preflight",
        backend="callable",
        design=design,
        seed=seed,
        algorithms=tuple(algorithms),
    )
    return check_seed_namespace(spec, where) + check_scale(spec, where)


def preflight_paper() -> list[Finding]:
    """Pre-flight the paper's full 3-benchmark x 3-chip matrix (the specs
    ``benchmarks.paper_matrix`` would run, sqlite-store configuration)."""
    from repro.core.api import TuningSpec
    from repro.core.experiment import ExperimentDesign

    benches = ("add", "harris", "mandelbrot")
    chips = ("v5e", "v4", "v3")
    algos = ("rs", "rf", "ga", "bo_gp", "bo_tpe")
    design = ExperimentDesign.paper()
    specs = []
    findings: list[Finding] = []
    for bench in benches:
        for chip in chips:
            spec = TuningSpec(
                kernel=bench,
                backend="costmodel",
                backend_kwargs={"chip": chip},
                algorithms=algos,
                design=design,
                cache_key=f"{bench}/{chip}",
                store="sqlite",
                store_path=f"results/paper_matrix/{bench}_{chip}_cache.sqlite",
            )
            specs.append(spec)
            findings += preflight_spec(spec, where=f"<paper:{bench}/{chip}>")
    findings += check_cache_key_namespaces(specs, where="<paper>")
    return findings
