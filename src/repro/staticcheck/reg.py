"""REG + import-based SER rules: the registries must be whole.

These checks import the real registries and inspect the live objects —
"static" in the sense of *before any compile or measurement*, not in the
sense of never executing Python.  They catch the registration drift the
AST rules cannot see: a searcher registered without ``_propose``, a backend
whose hooks are not callable, a kernel package that forgot to publish its
bench descriptor.

* **REG001** — every ``SEARCHERS`` entry subclasses ``Searcher``, overrides
  ``_propose``, and constructs from JSON kwargs (a smoke construction on a
  tiny space, plus a signature scan for non-JSON defaults).
* **REG002** — every ``BACKENDS`` / ``EXECUTORS`` / ``STORES`` entry is
  well-formed: callables where callables belong, the store interface
  complete (get/put/save/items + the meta side-channel journaling needs).
* **REG003** — every kernel in ``TUNABLE_KERNELS`` publishes a complete
  ``KernelBenchSpec`` (name, input builder, runner) into
  ``KERNEL_BENCHES``, and the two registries agree on the kernel set.
* **SER001** — ``TuningSpec`` JSON round-trips.
* **SER002** — registered constructor defaults on serializable paths are
  JSON-representable.
"""

from __future__ import annotations

import inspect

from .findings import Finding
from .ser import is_json_value


def _def_site(obj) -> tuple[str, int]:
    """(path, line) of a callable/class definition, for finding anchors."""
    try:
        path = inspect.getsourcefile(obj) or "<registry>"
        _, line = inspect.getsourcelines(obj)
        return path, line
    except (OSError, TypeError):
        return "<registry>", 0


def _finding(rule: str, obj, message: str, severity: str = "error") -> Finding:
    path, line = _def_site(obj)
    return Finding(
        path=path, line=line, rule=rule, message=message, severity=severity
    )


def _tiny_space():
    from repro.core.space import Param, SearchSpace

    return SearchSpace(
        [Param("t_x", (1, 2, 4)), Param("t_y", (1, 2)), Param("t_z", (1, 2))]
    )


def check_searchers() -> list[Finding]:
    from repro.core.searchers import SEARCHERS, make_searcher
    from repro.core.searchers.base import Searcher

    findings: list[Finding] = []
    space = _tiny_space()
    for name, cls in sorted(SEARCHERS.items()):
        if not (isinstance(cls, type) and issubclass(cls, Searcher)):
            findings.append(
                _finding(
                    "REG001",
                    cls,
                    f"SEARCHERS[{name!r}] is not a Searcher subclass",
                )
            )
            continue
        if cls._propose is Searcher._propose or getattr(
            cls._propose, "__isabstractmethod__", False
        ):
            findings.append(
                _finding(
                    "REG001",
                    cls,
                    f"SEARCHERS[{name!r}] does not implement _propose()",
                )
            )
        try:
            make_searcher(name, space, seed=0)
        except Exception as e:  # noqa: BLE001 — any ctor failure is the finding
            findings.append(
                _finding(
                    "REG001",
                    cls,
                    f"SEARCHERS[{name!r}] failed default construction: "
                    f"{type(e).__name__}: {e}",
                )
            )
        for pname, p in inspect.signature(cls.__init__).parameters.items():
            if pname in ("self", "space", "seed") or p.kind in (
                inspect.Parameter.VAR_POSITIONAL,
                inspect.Parameter.VAR_KEYWORD,
            ):
                continue
            if p.default is not inspect.Parameter.empty and not is_json_value(
                p.default
            ):
                findings.append(
                    _finding(
                        "SER002",
                        cls,
                        f"SEARCHERS[{name!r}] default {pname}="
                        f"{p.default!r} is not JSON-representable; specs "
                        "naming this searcher cannot shard",
                    )
                )
    return findings


def check_backends() -> list[Finding]:
    from repro.core.backends import BACKENDS

    findings: list[Finding] = []
    for name, backend in sorted(BACKENDS.items()):
        anchor = backend.make if callable(backend.make) else check_backends
        if not callable(backend.make):
            findings.append(
                _finding(
                    "REG002", anchor, f"BACKENDS[{name!r}].make is not callable"
                )
            )
            continue
        for hook in ("default_space", "true_optimum"):
            val = getattr(backend, hook)
            if val is not None and not callable(val):
                findings.append(
                    _finding(
                        "REG002",
                        anchor,
                        f"BACKENDS[{name!r}].{hook} is neither None nor "
                        "callable",
                    )
                )
        if backend.serializable:
            for pname, p in inspect.signature(backend.make).parameters.items():
                if p.default is not inspect.Parameter.empty and not (
                    is_json_value(p.default)
                ):
                    findings.append(
                        _finding(
                            "SER002",
                            backend.make,
                            f"BACKENDS[{name!r}] default {pname}="
                            f"{p.default!r} is not JSON-representable on a "
                            "serializable backend",
                        )
                    )
    return findings


def check_executors_and_stores() -> list[Finding]:
    from repro.core.executors import EXECUTORS
    from repro.core.stores import STORES

    findings: list[Finding] = []
    for name, ex in sorted(EXECUTORS.items()):
        if not callable(getattr(ex, "run", None)):
            findings.append(
                _finding(
                    "REG002",
                    type(ex),
                    f"EXECUTORS[{name!r}].run is not callable",
                )
            )
    required = ("get", "put", "save", "items", "get_meta", "put_meta",
                "get_winner", "put_winner", "winner_items")
    for name, cls in sorted(STORES.items()):
        missing = [m for m in required if not callable(getattr(cls, m, None))]
        if missing:
            findings.append(
                _finding(
                    "REG002",
                    cls,
                    f"STORES[{name!r}] ({cls.__name__}) is missing store "
                    f"interface methods: {', '.join(missing)}",
                )
            )
    return findings


def check_kernels() -> list[Finding]:
    from repro.kernels import KERNEL_BENCHES, TUNABLE_KERNELS
    from repro.kernels.common import KernelBenchSpec

    findings: list[Finding] = []
    for name in sorted(TUNABLE_KERNELS):
        if name not in KERNEL_BENCHES:
            findings.append(
                _finding(
                    "REG003",
                    TUNABLE_KERNELS[name],
                    f"kernel {name!r} is in TUNABLE_KERNELS but publishes no "
                    "KERNEL_BENCHES descriptor",
                )
            )
    for name, bench in sorted(KERNEL_BENCHES.items()):
        anchor = bench.run if callable(bench.run) else KernelBenchSpec
        if not isinstance(bench, KernelBenchSpec):
            findings.append(
                _finding(
                    "REG003",
                    anchor,
                    f"KERNEL_BENCHES[{name!r}] is not a KernelBenchSpec",
                )
            )
            continue
        if bench.name != name:
            findings.append(
                _finding(
                    "REG003",
                    anchor,
                    f"KERNEL_BENCHES[{name!r}].name is {bench.name!r} — the "
                    "registry key and descriptor disagree",
                )
            )
        for fld in ("make_inputs", "run"):
            if not callable(getattr(bench, fld)):
                findings.append(
                    _finding(
                        "REG003",
                        anchor,
                        f"KERNEL_BENCHES[{name!r}].{fld} is not callable — "
                        "the kernel/ops/ref triple is incomplete",
                    )
                )
        if name not in TUNABLE_KERNELS:
            findings.append(
                _finding(
                    "REG003",
                    anchor,
                    f"kernel {name!r} publishes a bench descriptor but has "
                    "no TUNABLE_KERNELS entry point",
                )
            )
    return findings


def check_spec_roundtrip() -> list[Finding]:
    from repro.core.api import TuningSpec
    from repro.core.experiment import ExperimentDesign

    spec = TuningSpec(
        kernel="harris",
        searcher="ga",
        searcher_kwargs={"pop_size": 16},
        backend_kwargs={"chip": "v5e"},
        design=ExperimentDesign.smoke(),
        algorithms=("rs", "ga"),
        store="json",
        store_path="cache.json",
    )
    findings: list[Finding] = []
    try:
        back = TuningSpec.from_json(spec.to_json())
        if back.to_dict() != spec.to_dict():
            findings.append(
                _finding(
                    "SER001",
                    TuningSpec,
                    "TuningSpec JSON round-trip is lossy: "
                    "from_json(to_json(spec)) != spec",
                )
            )
    except Exception as e:  # noqa: BLE001 — any round-trip failure is the finding
        findings.append(
            _finding(
                "SER001",
                TuningSpec,
                f"TuningSpec JSON round-trip raised {type(e).__name__}: {e}",
            )
        )
    for f in __import__("dataclasses").fields(TuningSpec):
        if f.default is not __import__("dataclasses").MISSING and not (
            is_json_value(f.default)
        ):
            findings.append(
                _finding(
                    "SER001",
                    TuningSpec,
                    f"TuningSpec.{f.name} default {f.default!r} is not "
                    "JSON-representable",
                )
            )
    return findings


def check_registries() -> list[Finding]:
    """All import-and-inspect checks; import failures become findings, not
    crashes (a broken registry module IS the finding)."""
    findings: list[Finding] = []
    for check in (
        check_searchers,
        check_backends,
        check_executors_and_stores,
        check_kernels,
        check_spec_roundtrip,
    ):
        try:
            findings.extend(check())
        except Exception as e:  # noqa: BLE001 — report, keep checking
            findings.append(
                Finding(
                    path="<registry>",
                    line=0,
                    rule="REG002",
                    message=(
                        f"{check.__name__} could not run: "
                        f"{type(e).__name__}: {e}"
                    ),
                )
            )
    return findings
