"""The invariant catalog: every rule the checker can emit, by id.

Rule ids are stable API — suppression comments, ``--select``, CI
annotations, and docs/static_analysis.md all refer to them.  Add rules;
never renumber them.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Rule:
    id: str
    family: str
    summary: str
    severity: str = "error"


_ALL = (
    # -- DET: determinism of results -----------------------------------------
    Rule(
        "DET001",
        "DET",
        "non-injected wall clock (time.time/perf_counter/datetime.now...) in "
        "a determinism-critical module; route through repro.core.clock",
    ),
    Rule(
        "DET002",
        "DET",
        "unseeded global randomness (np.random.* module state, stdlib "
        "random.*) in a determinism-critical module; use "
        "np.random.default_rng(seed)",
    ),
    Rule(
        "DET003",
        "DET",
        "iteration over an unordered set feeds downstream order; wrap in "
        "sorted() or use a deterministic container",
    ),
    # -- PROV: provenance / cache-key hygiene --------------------------------
    Rule(
        "PROV001",
        "PROV",
        "a speed knob (pipeline_workers/max_workers/executor/futures_pool) "
        "is injected into backend_kwargs but not excluded by a cache-key / "
        "journal-namespace / fingerprint sink",
    ),
    # -- OBS: observability isolation ----------------------------------------
    Rule(
        "OBS001",
        "OBS",
        "a telemetry/trace identifier appears inside a cache-key / "
        "journal-namespace / fingerprint sink — telemetry is a pure "
        "observability knob and must never reach run identity",
    ),
    # -- REG: registry completeness ------------------------------------------
    Rule(
        "REG001",
        "REG",
        "a SEARCHERS entry does not implement _propose or cannot be "
        "constructed from JSON kwargs",
    ),
    Rule(
        "REG002",
        "REG",
        "a BACKENDS / EXECUTORS / STORES entry is malformed (missing "
        "callables, wrong interface)",
    ),
    Rule(
        "REG003",
        "REG",
        "a kernel package publishes an incomplete kernel/ops/ref triple "
        "into KERNEL_BENCHES / TUNABLE_KERNELS",
    ),
    # -- SER: serialization ---------------------------------------------------
    Rule(
        "SER001",
        "SER",
        "TuningSpec does not JSON round-trip (field defaults or to_dict/"
        "from_dict drift)",
    ),
    Rule(
        "SER002",
        "SER",
        "a registered searcher/backend declares non-JSON-representable "
        "constructor defaults on a serializable path",
    ),
    Rule(
        "SER003",
        "SER",
        "a callable (lambda) is embedded in a *_kwargs dict bound for "
        "serialization",
    ),
    # -- LIB: library hygiene -------------------------------------------------
    Rule(
        "LIB001",
        "LIB",
        "bare assert used for a runtime error in library code (stripped "
        "under python -O); raise a real exception",
    ),
    # -- SPEC: the pre-flight (spec-level, not per-file) ----------------------
    Rule("SPEC001", "SPEC", "search-space size / constrained fraction", "info"),
    Rule(
        "SPEC002",
        "SPEC",
        "the constrained search space is empty or unsatisfiable",
    ),
    Rule(
        "SPEC003",
        "SPEC",
        "experiment-seed namespace collision: two cells share a cache/seed "
        "namespace entry",
    ),
    Rule(
        "SPEC004",
        "SPEC",
        "paper-scale design without a persistent measurement store",
        "warning",
    ),
    Rule(
        "SPEC005",
        "SPEC",
        "design rows with too few experiments for decidable claim verdicts",
        "info",
    ),
    # -- the checker itself ---------------------------------------------------
    Rule("PARSE", "PARSE", "file does not parse"),
)

RULES: dict[str, Rule] = {r.id: r for r in _ALL}

FAMILIES: tuple[str, ...] = tuple(
    sorted({r.family for r in _ALL if r.family != "PARSE"})
)


def resolve_select(select: str | None) -> frozenset[str] | None:
    """``--select`` tokens -> concrete rule-id set (families expand)."""
    if not select:
        return None
    out: set[str] = set()
    for tok in select.split(","):
        tok = tok.strip()
        if not tok:
            continue
        if tok in RULES:
            out.add(tok)
        elif any(r.family == tok for r in _ALL):
            out.update(r.id for r in _ALL if r.family == tok)
        else:
            raise KeyError(
                f"unknown rule or family {tok!r}; see --list-rules"
            )
    return frozenset(out)
